"""Edge/DC inference engine — the paper's "E"(stimate) operation.

Two request kinds, matching the paper's two model classes:
  * ``BatchEngine``  — stateless batched inference (BraggNN / CookieNetAE at
    the edge): dynamic micro-batching with a latency budget, padded to fixed
    compiled batch sizes (edge accelerators compile fixed shapes).
  * ``DecodeEngine`` — autoregressive LM serving with a KV/recurrent-state
    cache and continuous slot management (admit new requests into free cache
    slots between steps), built on each model family's ``decode_step``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BatchStats:
    n_requests: int = 0
    n_batches: int = 0
    total_items: int = 0
    total_latency: float = 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "requests": self.n_requests,
            "batches": self.n_batches,
            "items": self.total_items,
            "mean_latency_s": self.total_latency / max(self.n_batches, 1),
        }


class BatchEngine:
    """Fixed-shape compiled batched inference with padding.

    ``apply_fn(params, x) -> y``; compiled once per allowed batch size
    (powers of two up to ``max_batch``), requests padded up to the nearest.
    """

    def __init__(self, apply_fn: Callable, params: PyTree, *,
                 max_batch: int = 1024) -> None:
        self.params = params
        self.max_batch = max_batch
        self._jitted = jax.jit(apply_fn)
        self.stats = BatchStats()

    def _padded_size(self, n: int) -> int:
        size = 1
        while size < n:
            size *= 2
        return min(size, self.max_batch)

    def infer(self, x: np.ndarray) -> np.ndarray:
        """Process a request of any size by padded fixed-shape batches."""
        self.stats.n_requests += 1
        outs = []
        i = 0
        n = x.shape[0]
        while i < n:
            take = min(self.max_batch, n - i)
            size = self._padded_size(take)
            chunk = x[i:i + take]
            if take < size:
                pad = np.zeros((size - take,) + x.shape[1:], x.dtype)
                chunk = np.concatenate([chunk, pad])
            t0 = time.perf_counter()
            y = np.asarray(self._jitted(self.params, jnp.asarray(chunk)))
            self.stats.total_latency += time.perf_counter() - t0
            self.stats.n_batches += 1
            self.stats.total_items += take
            outs.append(y[:take])
            i += take
        return np.concatenate(outs) if len(outs) > 1 else outs[0]


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    """Continuous-batching LM decode over a fixed slot grid.

    The cache has ``n_slots`` request slots; each engine step decodes one
    token for every active slot.  Finished slots are freed and refilled from
    the admission queue; prompts are fed token-by-token (prefill-as-decode,
    correct for every family incl. recurrent/SSM models).
    """

    def __init__(self, model_api, params: PyTree, *, n_slots: int,
                 cache_len: int, eos_token: int = -1,
                 window: int = 0) -> None:
        self.api = model_api
        self.params = params
        self.n_slots = n_slots
        self.eos = eos_token
        self.window = window
        self.cache = model_api.init_cache(n_slots, cache_len, window=window)
        self._step = jax.jit(
            lambda p, c, t: model_api.decode_step(p, c, t, window=window))
        self.active: List[Optional[Request]] = [None] * n_slots
        self.queue: List[Request] = []
        self._next_id = 0
        self.tokens_decoded = 0
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return rid

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.active[slot] is None and self.queue:
                self.active[slot] = self.queue.pop(0)
                self.active[slot]._cursor = 0     # type: ignore[attr-defined]

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: one token per active slot."""
        self._admit()
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            cur = req._cursor                      # type: ignore[attr-defined]
            if cur < len(req.prompt):
                tokens[slot, 0] = req.prompt[cur]
            elif req.generated:
                tokens[slot, 0] = req.generated[-1]
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens))
        next_tokens = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        self.steps += 1

        for slot, req in enumerate(self.active):
            if req is None:
                continue
            cur = req._cursor                      # type: ignore[attr-defined]
            req._cursor = cur + 1                  # type: ignore[attr-defined]
            if cur >= len(req.prompt) - 1:         # now generating
                tok = int(next_tokens[slot])
                req.generated.append(tok)
                self.tokens_decoded += 1
                if (len(req.generated) >= req.max_new_tokens
                        or tok == self.eos):
                    req.done = True
                    self.active[slot] = None

    def run_until_drained(self, max_steps: int = 100_000) -> List[Request]:
        finished: List[Request] = []
        seen: Dict[int, Request] = {}
        pending = list(self.queue)
        for r in pending:
            seen[r.request_id] = r
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                break
            for a in self.active:
                if a is not None:
                    seen[a.request_id] = a
            self.step()
        finished = [r for r in seen.values() if r.done]
        return finished
